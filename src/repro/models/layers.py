"""Transformer building blocks shared by the architecture zoo.

All layers are plain functions over parameter dicts (pytrees of arrays or
ShapeDtypeStructs via :mod:`repro.models.param`), so a single definition
serves training, prefill and decode, and lowers cleanly under pjit on the
production meshes.

Attention supports GQA (+ optional QKV bias, sliding window) and three KV
cache layouts:
  * contiguous — (B, S_max, Hkv, D), classic serving cache
  * paged      — (N_blocks, block, Hkv, D) pool + (B, max_blocks) block
                 tables; pages are recycled through the stamped BlockPool
                 (the paper's technique at the serving layer)
  * rolling    — (B, window, Hkv, D) ring buffer for sliding-window models
                 (mixtral long-context decode)
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .param import ParamSpec

# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------
def norm_specs(cfg: ModelConfig, layered: bool = True) -> ParamSpec:
    lead = (cfg.num_layers,) if layered else ()
    lead_ax = ("layers",) if layered else ()
    return {
        "scale": ParamSpec(lead + (cfg.d_model,), lead_ax + ("embed",),
                           init="ones")
    }


def apply_norm(p, x, cfg: ModelConfig):
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        xf = xf - mu
    var = jnp.mean(jnp.square(xf), -1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + cfg.norm_eps) * p["scale"].astype(
        jnp.float32
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------
def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D) with positions (..., S) — rotate pairs."""
    D = x.shape[-1]
    half = D // 2
    freqs = 1.0 / (
        theta ** (jnp.arange(0, half, dtype=jnp.float32) / half)
    )
    angles = positions[..., None].astype(jnp.float32) * freqs  # (...,S,half)
    cos = jnp.cos(angles)[..., None, :]  # (...,S,1,half)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = x[..., :half], x[..., half:]
    xf1, xf2 = x1.astype(jnp.float32), x2.astype(jnp.float32)
    out = jnp.concatenate(
        [xf1 * cos - xf2 * sin, xf2 * cos + xf1 * sin], axis=-1
    )
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Attention
# ---------------------------------------------------------------------------
def attention_specs(
    cfg: ModelConfig, layers: int, heads: Optional[int] = None
) -> Dict[str, ParamSpec]:
    H = heads or cfg.num_heads
    Hkv = cfg.num_kv_heads or H
    D = cfg.resolved_head_dim
    M = cfg.d_model
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    specs = {
        "wq": ParamSpec(lead + (M, H, D), la + ("embed", "heads", None),
                        init="scaled"),
        "wk": ParamSpec(lead + (M, Hkv, D), la + ("embed", "kv_heads", None),
                        init="scaled"),
        "wv": ParamSpec(lead + (M, Hkv, D), la + ("embed", "kv_heads", None),
                        init="scaled"),
        "wo": ParamSpec(lead + (H, D, M), la + ("heads", None, "embed"),
                        init="scaled"),
    }
    if cfg.qkv_bias:
        specs["bq"] = ParamSpec(lead + (H, D), la + ("heads", None),
                                init="zeros")
        specs["bk"] = ParamSpec(lead + (Hkv, D), la + ("kv_heads", None),
                                init="zeros")
        specs["bv"] = ParamSpec(lead + (Hkv, D), la + ("kv_heads", None),
                                init="zeros")
    return specs


def _project_qkv(p, x, cfg: ModelConfig):
    dt = x.dtype
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(dt))
    k = jnp.einsum("bsm,mhd->bshd", x, p["wk"].astype(dt))
    v = jnp.einsum("bsm,mhd->bshd", x, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    return q, k, v


def attention_full(
    p,
    x: jax.Array,  # (B, S, M)
    cfg: ModelConfig,
    *,
    positions: Optional[jax.Array] = None,  # (S,) absolute positions
    causal: bool = True,
    kv_x: Optional[jax.Array] = None,  # cross-attention source (B, S_kv, M)
    use_rope: bool = True,
) -> Tuple[jax.Array, Tuple[jax.Array, jax.Array]]:
    """Full-sequence attention (train / prefill / encoder / cross).

    Returns (out (B,S,M), (k, v)) so prefill can populate the cache.
    """
    B, S, M = x.shape
    dt = x.dtype
    src = kv_x if kv_x is not None else x
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(dt))
    k = jnp.einsum("bsm,mhd->bshd", src, p["wk"].astype(dt))
    v = jnp.einsum("bsm,mhd->bshd", src, p["wv"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
        k = k + p["bk"].astype(dt)
        v = v + p["bv"].astype(dt)
    if use_rope and kv_x is None:
        pos = positions if positions is not None else jnp.arange(S)
        q = apply_rope(q, pos, cfg.rope_theta)
        k = apply_rope(k, pos, cfg.rope_theta)
    out = ops.flash_attention(
        q, k, v, causal=causal, window=cfg.sliding_window
    )
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(dt))
    return out, (k, v)


def attention_decode(
    p,
    x: jax.Array,  # (B, 1, M) — one new token per sequence
    cfg: ModelConfig,
    cache: Dict[str, jax.Array],  # per-layer slice (no leading L dim)
    lengths: jax.Array,  # (B,) tokens already in cache
    *,
    block_table: Optional[jax.Array] = None,  # (B, max_blocks) for paged
    n_kv: Optional[int] = None,  # static bound on the paged KV sweep
    global_pages: bool = False,  # table holds slot-flattened global ids
    use_rope: bool = True,
    cross: bool = False,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Single-token decode against a KV cache (contiguous/paged/rolling).

    Cross-attention decode reads a fixed cache and writes nothing.
    ``n_kv`` bounds the paged-attention page sweep (local path only; the
    context-parallel distributed path always sweeps its stripe).
    ``global_pages`` switches the paged path to slot-flattened GLOBAL page
    ids (``slot * N_pool + page``): a block-table row may then reference
    pages physically owned by another slot — how copy-on-write forks share
    one prompt prefix across N branches.
    """
    B, S1, M = x.shape
    assert S1 == 1
    dt = x.dtype
    q = jnp.einsum("bsm,mhd->bshd", x, p["wq"].astype(dt))
    if "bq" in p:
        q = q + p["bq"].astype(dt)
    if use_rope and not cross:
        q = apply_rope(q, lengths[:, None], cfg.rope_theta)
    q1 = q[:, 0]  # (B, H, D)

    if cross:
        out = ops.decode_attention(q1, cache["k"], cache["v"], cache["len"])
        out = jnp.einsum("bhd,hdm->bm", out, p["wo"].astype(dt))
        return out[:, None], cache

    k_new = jnp.einsum("bsm,mhd->bshd", x, p["wk"].astype(dt))
    v_new = jnp.einsum("bsm,mhd->bshd", x, p["wv"].astype(dt))
    if "bk" in p:
        k_new = k_new + p["bk"].astype(dt)
        v_new = v_new + p["bv"].astype(dt)
    if use_rope:
        k_new = apply_rope(k_new, lengths[:, None], cfg.rope_theta)
    k1, v1 = k_new[:, 0], v_new[:, 0]  # (B, Hkv, D)

    if block_table is not None:
        dist = ops.dist_decode_config()
        hkv = cfg.num_kv_heads or cfg.num_heads
        if dist is not None and hkv % 16 != 0:
            # §Perf iteration 2: context-parallel flash-decode over the
            # page-striped pool (no pool all-gathers).  The striped kernel
            # addresses (slot, page) pairs, so cross-slot CoW refs are not
            # representable: global ids fold back to local — correct only
            # while every row references its own slot's pages (the engine
            # keeps forking off when page striping is active).
            from ..kernels.distributed import paged_attention_dist

            n_pool = cache["k_pool"].shape[1]
            dist_table = (block_table % n_pool if global_pages
                          else block_table)
            out, k_pool, v_pool = paged_attention_dist(
                q1, cache["k_pool"], cache["v_pool"], dist_table,
                lengths, k1, v1, mesh=dist["mesh"],
                batch_part=dist["batch_part"], axis=dist["axis"],
            )
            out = jnp.einsum("bhd,hdm->bm", out, p["wo"].astype(dt))
            return out[:, None], dict(cache, k_pool=k_pool, v_pool=v_pool)
        block = cache["k_pool"].shape[2]
        barange = jnp.arange(B)
        if global_pages:
            # ---- paged cache, slot-flattened global ids (CoW forks) ----
            n_pool = cache["k_pool"].shape[1]
            Hkv, D = cache["k_pool"].shape[3], cache["k_pool"].shape[4]
            page_g = block_table[barange, lengths // block]  # (B,) global
            offs = lengths % block
            kfl = cache["k_pool"].reshape(B * n_pool, block, Hkv, D)
            vfl = cache["v_pool"].reshape(B * n_pool, block, Hkv, D)
            # inactive slots' zero rows all land on global page 0 (slot
            # 0's scratch page) — never read, same contract as the local
            # path's per-slot scratch page
            kfl = kfl.at[page_g, offs].set(k1)
            vfl = vfl.at[page_g, offs].set(v1)
            k_pool = kfl.reshape(cache["k_pool"].shape)
            v_pool = vfl.reshape(cache["v_pool"].shape)
            out = ops.paged_attention(
                q1, k_pool, v_pool, block_table, lengths + 1, n_kv=n_kv,
                global_pages=True,
            )
            new_cache = dict(cache, k_pool=k_pool, v_pool=v_pool)
        else:
            # ---- paged cache (per-sequence-local pools) ----
            page = block_table[barange, lengths // block]  # (B,) local id
            slot = lengths % block
            k_pool = cache["k_pool"].at[barange, page, slot].set(k1)
            v_pool = cache["v_pool"].at[barange, page, slot].set(v1)
            out = ops.paged_attention(
                q1, k_pool, v_pool, block_table, lengths + 1, n_kv=n_kv
            )
            new_cache = dict(cache, k_pool=k_pool, v_pool=v_pool)
    elif cfg.sliding_window and cache["k"].shape[1] == cfg.sliding_window:
        # ---- rolling (sliding-window) cache ----
        W = cfg.sliding_window
        dist = ops.dist_decode_config()
        if dist is not None and W % 16 == 0:
            from ..kernels.distributed import rolling_attention_dist

            out, k_c, v_c = rolling_attention_dist(
                q1, cache["k"], cache["v"], lengths, k1, v1,
                mesh=dist["mesh"], batch_part=dist["batch_part"],
                axis=dist["axis"],
            )
            out = jnp.einsum("bhd,hdm->bm", out, p["wo"].astype(dt))
            return out[:, None], dict(cache, k=k_c, v=v_c)
        slot = lengths % W
        k_c = cache["k"].at[jnp.arange(B), slot].set(k1)
        v_c = cache["v"].at[jnp.arange(B), slot].set(v1)
        valid = jnp.minimum(lengths + 1, W)
        out = ops.decode_attention(q_rolling(q1, cfg), k_c, v_c, valid)
        new_cache = dict(cache, k=k_c, v=v_c)
    else:
        # ---- contiguous cache ----
        k_c = cache["k"].at[jnp.arange(B), lengths].set(k1)
        v_c = cache["v"].at[jnp.arange(B), lengths].set(v1)
        out = ops.decode_attention(q1, k_c, v_c, lengths + 1)
        new_cache = dict(cache, k=k_c, v=v_c)

    out = jnp.einsum("bhd,hdm->bm", out, p["wo"].astype(dt))
    return out[:, None], new_cache


def attention_chunk(
    p,
    x: jax.Array,  # (1, C, M) — one prefill chunk for one slot
    cfg: ModelConfig,
    cache: Dict[str, jax.Array],  # per-layer slice (no leading L dim)
    *,
    slot: jax.Array,       # scalar int32 — the admitting slot
    row: jax.Array,        # (mb,) int32 — block-table row incl. this chunk
    pages: jax.Array,      # (nc,) int32 — pages this chunk writes
    positions: jax.Array,  # (C,) int32 — absolute token positions
    n_kv: int,             # static bound on the prior-KV page sweep
    global_pages: bool = False,  # row/pages hold slot-flattened global ids
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Chunked-prefill attention against a paged KV cache.

    The chunk's K/V are scattered into the slot's pool pages at the chunk
    offsets FIRST, then the queries attend causally (``q_offset`` masking)
    over the first ``n_kv`` pages of the slot's block-table row — which
    now hold every earlier chunk AND this one.  Padded / unallocated
    positions sit past the causal horizon, so their (garbage) keys mask to
    exact zeros: the output at every valid position is bit-identical to a
    whole-prompt prefill of the same tokens (asserted in
    tests/test_chunked_prefill.py).

    With ``global_pages`` the ``row``/``pages`` operands carry global ids
    into the slot-flattened pool (``slot`` is then only the scratch-row
    owner); writes and the row gather address the flat pool directly.
    """
    B, C, M = x.shape
    dt = x.dtype
    q, k, v = _project_qkv(p, x, cfg)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    block = cache["k_pool"].shape[2]
    Hkv, D = cache["k_pool"].shape[3], cache["k_pool"].shape[4]
    nc = C // block
    if global_pages:
        n_slots, n_pool = cache["k_pool"].shape[0], cache["k_pool"].shape[1]
        kfl = cache["k_pool"].reshape(n_slots * n_pool, block, Hkv, D)
        vfl = cache["v_pool"].reshape(n_slots * n_pool, block, Hkv, D)
        kfl = kfl.at[pages].set(
            k[0].reshape(nc, block, Hkv, D).astype(kfl.dtype)
        )
        vfl = vfl.at[pages].set(
            v[0].reshape(nc, block, Hkv, D).astype(vfl.dtype)
        )
        gk = kfl[row[:n_kv]].reshape(1, n_kv * block, Hkv, D)
        gv = vfl[row[:n_kv]].reshape(1, n_kv * block, Hkv, D)
        kp = kfl.reshape(cache["k_pool"].shape)
        vp = vfl.reshape(cache["v_pool"].shape)
    else:
        kp = cache["k_pool"].at[slot, pages].set(
            k[0].reshape(nc, block, Hkv, D).astype(cache["k_pool"].dtype)
        )
        vp = cache["v_pool"].at[slot, pages].set(
            v[0].reshape(nc, block, Hkv, D).astype(cache["v_pool"].dtype)
        )
        gk = kp[slot][row[:n_kv]].reshape(1, n_kv * block, Hkv, D)
        gv = vp[slot][row[:n_kv]].reshape(1, n_kv * block, Hkv, D)
    out = ops.flash_attention(q, gk, gv, causal=True, q_offset=positions[0])
    out = jnp.einsum("bshd,hdm->bsm", out, p["wo"].astype(dt))
    return out, dict(cache, k_pool=kp, v_pool=vp)


def q_rolling(q1: jax.Array, cfg: ModelConfig) -> jax.Array:
    """Rolling caches lose absolute slot order; attention over a ring is
    order-invariant under softmax (positions already baked into k via
    RoPE), so q passes through unchanged."""
    return q1


# ---------------------------------------------------------------------------
# Dense MLP (SwiGLU)
# ---------------------------------------------------------------------------
def mlp_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    M, F = cfg.d_model, cfg.d_ff
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "wi_gate": ParamSpec(lead + (M, F), la + ("embed", "mlp"),
                             init="scaled"),
        "wi_up": ParamSpec(lead + (M, F), la + ("embed", "mlp"),
                           init="scaled"),
        "wo": ParamSpec(lead + (F, M), la + ("mlp", "embed"), init="scaled"),
    }


def apply_mlp(p, x, cfg: ModelConfig):
    dt = x.dtype
    g = jnp.einsum("bsm,mf->bsf", x, p["wi_gate"].astype(dt))
    u = jnp.einsum("bsm,mf->bsf", x, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    return jnp.einsum("bsf,fm->bsm", h, p["wo"].astype(dt))


# ---------------------------------------------------------------------------
# MoE (top-k, scatter-based dropping dispatch — no one-hot einsum FLOPs)
# ---------------------------------------------------------------------------
def moe_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    M, F, E = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "router": ParamSpec(lead + (M, E), la + ("embed", None),
                            init="scaled"),
        "wi_gate": ParamSpec(lead + (E, M, F),
                             la + ("experts", "embed", "mlp"), init="scaled"),
        "wi_up": ParamSpec(lead + (E, M, F),
                           la + ("experts", "embed", "mlp"), init="scaled"),
        "wo": ParamSpec(lead + (E, F, M),
                        la + ("experts", "mlp", "embed"), init="scaled"),
    }


def apply_moe(p, x, cfg: ModelConfig):
    """Top-k MoE with capacity-bounded, batched PER-ROW scatter dispatch.

    All dispatch bookkeeping (top-k, counts, ranks, scatter/gather) is
    batched over the leading batch dim and never mixes tokens across rows,
    so under GSPMD it partitions cleanly on the data axis with NO global
    sort / resharding collectives (§Perf iteration on the MoE cells; the
    earlier flat-token formulation forced TB-scale all-reduces).  Gather/
    scatter are memory ops, so HLO FLOPs stay equal to the *active*
    expert FLOPs (no GShard one-hot einsum fake-FLOPs).
    """
    dist = ops.dist_moe_config()
    if dist is not None:
        from ..kernels.distributed import moe_block_dist

        return moe_block_dist(p, x, cfg, mesh=dist["mesh"],
                              batch_part=dist["batch_part"],
                              axis=dist["axis"])
    B, S, M = x.shape
    E, k = cfg.num_experts, cfg.experts_per_token
    # per-row, per-expert capacity (dropless for S == 1 decode)
    C = max(int(cfg.moe_capacity_factor * S * k / E), k)
    C = min(C, S * k)
    dt = x.dtype
    b_ix = jnp.arange(B)[:, None]

    logits = jnp.einsum("bsm,me->bse", x, p["router"].astype(dt))
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    gate_w, ids = jax.lax.top_k(probs, k)          # (B, S, k)
    gate_w = gate_w / jnp.maximum(gate_w.sum(-1, keepdims=True), 1e-9)

    flat_ids = ids.reshape(B, S * k)
    tok_of = jnp.repeat(jnp.arange(S, dtype=jnp.int32), k)  # (S*k,)
    order = jnp.argsort(flat_ids, axis=-1, stable=True)     # per-row sort
    sorted_ids = jnp.take_along_axis(flat_ids, order, -1)
    sorted_tok = jnp.broadcast_to(tok_of[None], (B, S * k))
    sorted_tok = jnp.take_along_axis(sorted_tok, order, -1)
    sorted_w = jnp.take_along_axis(gate_w.reshape(B, S * k), order, -1)

    counts = jnp.zeros((B, E), jnp.int32).at[b_ix, flat_ids].add(1)
    starts = jnp.concatenate(
        [jnp.zeros((B, 1), jnp.int32), jnp.cumsum(counts, -1)[:, :-1]], -1
    )
    pos = (
        jnp.arange(S * k, dtype=jnp.int32)[None]
        - jnp.take_along_axis(starts, sorted_ids, -1)
    )
    valid = pos < C
    pos_c = jnp.where(valid, pos, C)               # overflow slot (dropped)

    gathered = jnp.take_along_axis(
        x, sorted_tok[..., None], axis=1
    )                                              # (B, S*k, M)
    buf = jnp.zeros((B, E, C + 1, M), dt)
    buf = buf.at[b_ix, sorted_ids, pos_c].set(gathered)
    buf = buf[:, :, :C]

    g = jnp.einsum("becm,emf->becf", buf, p["wi_gate"].astype(dt))
    u = jnp.einsum("becm,emf->becf", buf, p["wi_up"].astype(dt))
    h = jax.nn.silu(g) * u
    y = jnp.einsum("becf,efm->becm", h, p["wo"].astype(dt))

    y = jnp.pad(y, ((0, 0), (0, 0), (0, 1), (0, 0)))
    contrib = y[b_ix, sorted_ids, pos_c] * (
        sorted_w * valid.astype(jnp.float32)
    ).astype(dt)[..., None]
    out = jnp.zeros((B, S, M), dt).at[b_ix, sorted_tok].add(contrib)
    return out


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------
def embed_specs(cfg: ModelConfig) -> Dict[str, ParamSpec]:
    specs = {
        "tok": ParamSpec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"))
    }
    if not cfg.tie_embeddings:
        specs["unembed"] = ParamSpec(
            (cfg.d_model, cfg.vocab_size), ("embed", "vocab"), init="scaled"
        )
    return specs


def embed_tokens(p, tokens: jax.Array, cfg: ModelConfig) -> jax.Array:
    return p["tok"].astype(jnp.dtype(cfg.dtype))[tokens]


def unembed(p, x: jax.Array, cfg: ModelConfig) -> jax.Array:
    dt = x.dtype
    if cfg.tie_embeddings:
        return jnp.einsum("b...m,vm->b...v", x, p["tok"].astype(dt))
    return jnp.einsum("b...m,mv->b...v", x, p["unembed"].astype(dt))
