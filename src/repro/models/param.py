"""Parameter declaration machinery.

Models declare their parameters once as a pytree of :class:`ParamSpec`
(shape + dtype + logical axis names + initializer).  From that single
declaration we derive:

  * ``init_params``      — materialized arrays (for smoke tests / examples)
  * ``abstract_params``  — ``jax.ShapeDtypeStruct`` stand-ins (dry-run: no
                           allocation, exactly the shannon/kernels pattern)
  * ``partition_specs``  — ``PartitionSpec`` per param from logical→mesh
                           axis rules (see :mod:`repro.sharding`)

Logical axis names used across the model zoo:
  ``layers``   leading stacked-layer axis (scanned)
  ``embed``    d_model dim (FSDP-shardable)
  ``heads``    attention-head / head*head_dim dim (tensor-parallel)
  ``kv_heads`` kv-head dim
  ``mlp``      feed-forward hidden dim (tensor-parallel)
  ``vocab``    vocabulary dim (tensor-parallel)
  ``experts``  MoE expert dim (expert-parallel)
  ``ssm_inner``/``ssm_state``  Mamba2 inner / state dims
  ``None``     replicated dim
"""

from __future__ import annotations

import dataclasses
import hashlib
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ParamSpec:
    shape: Tuple[int, ...]
    axes: Tuple[Optional[str], ...]
    dtype: Any = jnp.float32
    init: str = "normal"  # normal | zeros | ones | scaled
    scale: float = 0.02

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, ParamSpec)


def tree_map_specs(fn: Callable[[str, ParamSpec], Any], tree, prefix=""):
    """Map over a nested-dict tree of ParamSpec with path strings."""
    if is_spec(tree):
        return fn(prefix, tree)
    assert isinstance(tree, dict), f"unexpected leaf at {prefix}: {tree!r}"
    return {
        k: tree_map_specs(fn, v, f"{prefix}/{k}" if prefix else k)
        for k, v in tree.items()
    }


def abstract_params(spec_tree):
    """ShapeDtypeStruct tree — dry-run stand-ins, no device allocation."""
    return tree_map_specs(
        lambda path, s: jax.ShapeDtypeStruct(s.shape, s.dtype), spec_tree
    )


def _path_seed(path: str, base: int) -> int:
    h = hashlib.md5(path.encode()).digest()
    return (base + int.from_bytes(h[:4], "little")) % (2**31)


def init_params(spec_tree, seed: int = 0):
    """Materialize parameters (smoke tests, examples, real training)."""

    def make(path: str, s: ParamSpec):
        if s.init == "zeros":
            return jnp.zeros(s.shape, s.dtype)
        if s.init == "ones":
            return jnp.ones(s.shape, s.dtype)
        key = jax.random.PRNGKey(_path_seed(path, seed))
        if s.init == "scaled":  # fan-in scaled
            fan_in = s.shape[-2] if len(s.shape) >= 2 else s.shape[-1]
            scale = 1.0 / np.sqrt(max(fan_in, 1))
            return (
                jax.random.normal(key, s.shape, jnp.float32) * scale
            ).astype(s.dtype)
        return (
            jax.random.normal(key, s.shape, jnp.float32) * s.scale
        ).astype(s.dtype)

    return tree_map_specs(make, spec_tree)


def logical_axes(spec_tree):
    """Parallel tree of logical-axis tuples (for sharding rules)."""
    return tree_map_specs(lambda path, s: s.axes, spec_tree)


def count_params(spec_tree) -> int:
    total = 0

    def add(path, s):
        nonlocal total
        total += int(np.prod(s.shape))

    tree_map_specs(add, spec_tree)
    return total
