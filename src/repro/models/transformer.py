"""Architecture-zoo model definitions: decoder-only (dense/MoE/VLM),
Mamba2 (SSM), Zamba2-style hybrid, and encoder-decoder stacks.

Design:
  * One ParamSpec tree per config (``build_specs``): layer params stacked
    over a leading ``layers`` axis and run with ``lax.scan`` (keeps HLO and
    compile time O(1) in depth — essential for 33 dry-run cells x 2 meshes).
  * Training bodies are wrapped in ``jax.checkpoint`` (full remat by
    default, policy configurable for the §Perf hillclimb).
  * An optional ``constrain(x)`` hook applies sequence-parallel sharding
    constraints on the residual stream between layers (Megatron-SP): the
    saved remat carries are then sharded over the `model` axis, which is
    what makes 34B-scale training fit HBM.
  * Caches are declared as ParamSpec trees too, so dry-run abstract values
    and shardings come from the same machinery as params.
"""

from __future__ import annotations

import functools
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from . import layers as L
from . import ssm as S
from .param import ParamSpec

Constrain = Callable[[jax.Array], jax.Array]
_id: Constrain = lambda x: x

#: KV page size (TPU lane-aligned)
BLOCK_SIZE = 128
#: production tensor-parallel width (both meshes use model=16)
TP_WIDTH = 16


# ---------------------------------------------------------------------------
# Param specs
# ---------------------------------------------------------------------------
def _decoder_layer_specs(cfg: ModelConfig, n: int) -> Dict[str, Any]:
    specs = {
        "norm1": {"scale": ParamSpec((n, cfg.d_model), ("layers", "embed"),
                                     init="ones")},
        "attn": L.attention_specs(cfg, n),
        "norm2": {"scale": ParamSpec((n, cfg.d_model), ("layers", "embed"),
                                     init="ones")},
    }
    if cfg.family == "moe":
        specs["moe"] = L.moe_specs(cfg, n)
    else:
        specs["mlp"] = L.mlp_specs(cfg, n)
    return specs


def build_specs(cfg: ModelConfig) -> Dict[str, Any]:
    specs: Dict[str, Any] = {"embed": L.embed_specs(cfg)}
    if cfg.family in ("dense", "moe", "vlm"):
        specs["layers"] = _decoder_layer_specs(cfg, cfg.num_layers)
    elif cfg.family == "ssm":
        specs["layers"] = {
            "norm": {"scale": ParamSpec((cfg.num_layers, cfg.d_model),
                                        ("layers", "embed"), init="ones")},
            "mamba": S.mamba_specs(cfg, cfg.num_layers),
        }
    elif cfg.family == "hybrid":
        specs["layers"] = {
            "norm": {"scale": ParamSpec((cfg.num_layers, cfg.d_model),
                                        ("layers", "embed"), init="ones")},
            "mamba": S.mamba_specs(cfg, cfg.num_layers),
        }
        # one shared attention block, applied every attn_period layers
        shared = {
            "norm1": {"scale": ParamSpec((cfg.d_model,), ("embed",),
                                         init="ones")},
            "attn": L.attention_specs(cfg, 0),
            "norm2": {"scale": ParamSpec((cfg.d_model,), ("embed",),
                                         init="ones")},
            "mlp": L.mlp_specs(cfg, 0),
        }
        specs["shared_attn"] = shared
    elif cfg.family == "encdec":
        ne, nd = cfg.encoder_layers, cfg.num_layers
        specs["enc_layers"] = {
            "norm1": {"scale": ParamSpec((ne, cfg.d_model),
                                         ("layers", "embed"), init="ones")},
            "attn": L.attention_specs(cfg, ne),
            "norm2": {"scale": ParamSpec((ne, cfg.d_model),
                                         ("layers", "embed"), init="ones")},
            "mlp": L.mlp_specs(cfg, ne),
        }
        specs["enc_norm"] = {
            "scale": ParamSpec((cfg.d_model,), ("embed",), init="ones")
        }
        specs["dec_layers"] = {
            "norm1": {"scale": ParamSpec((nd, cfg.d_model),
                                         ("layers", "embed"), init="ones")},
            "self_attn": L.attention_specs(cfg, nd),
            "norm_x": {"scale": ParamSpec((nd, cfg.d_model),
                                          ("layers", "embed"), init="ones")},
            "cross_attn": L.attention_specs(cfg, nd),
            "norm2": {"scale": ParamSpec((nd, cfg.d_model),
                                         ("layers", "embed"), init="ones")},
            "mlp": L.mlp_specs(cfg, nd),
        }
    else:  # pragma: no cover
        raise ValueError(f"unknown family {cfg.family}")
    specs["final_norm"] = {
        "scale": ParamSpec((cfg.d_model,), ("embed",), init="ones")
    }
    return specs


# ---------------------------------------------------------------------------
# Cache specs (decode / prefill-output)
# ---------------------------------------------------------------------------
def cache_layout(cfg: ModelConfig) -> str:
    if cfg.family == "ssm":
        return "ssm"
    if cfg.family == "hybrid":
        return "hybrid"
    if cfg.sliding_window > 0:
        return "rolling"
    return "paged"


def paged_blocks_sharded_cfg(cfg: ModelConfig) -> bool:
    """True when the paged pool stripes PAGES over `model` (kv heads do
    not divide the TP width, so head-sharding is unavailable)."""
    hkv = cfg.num_kv_heads or cfg.num_heads
    return hkv % TP_WIDTH != 0


def n_shared_attn(cfg: ModelConfig) -> int:
    return cfg.num_layers // cfg.attn_period if cfg.attn_period else 0


def _attn_cache_specs(cfg, n_layers, batch, max_seq, layout, dtype,
                      pool_slack: int = 0):
    Hkv = cfg.num_kv_heads or cfg.num_heads
    D = cfg.resolved_head_dim
    if layout == "rolling":
        W = min(cfg.sliding_window, max_seq)
        return {
            "k": ParamSpec((n_layers, batch, W, Hkv, D),
                           ("layers", "batch", "window", "kv_heads", None),
                           dtype=dtype, init="zeros"),
            "v": ParamSpec((n_layers, batch, W, Hkv, D),
                           ("layers", "batch", "window", "kv_heads", None),
                           dtype=dtype, init="zeros"),
        }
    if layout == "contiguous":
        return {
            "k": ParamSpec((n_layers, batch, max_seq, Hkv, D),
                           ("layers", "batch", "kv_seq", "kv_heads", None),
                           dtype=dtype, init="zeros"),
            "v": ParamSpec((n_layers, batch, max_seq, Hkv, D),
                           ("layers", "batch", "kv_seq", "kv_heads", None),
                           dtype=dtype, init="zeros"),
        }
    # paged (per-sequence-local pools).  Sharding choice (§Perf iter 1/1b):
    #   * kv_heads divisible by the TP width -> shard kv heads (gathers
    #     stay local, no pool collectives);
    #   * otherwise stripe the PAGES over `model` (pool page count rounded
    #     to a TP_WIDTH multiple so the dim divides) and use the
    #     distributed flash-decode (kernels/distributed.py) to avoid pool
    #     all-gathers.
    mb = -(-max_seq // BLOCK_SIZE) + 1 + pool_slack
    if pool_slack == 0 and paged_blocks_sharded_cfg(cfg):
        mb = -(-mb // TP_WIDTH) * TP_WIDTH
    blocks_ax = "blocks" if paged_blocks_sharded_cfg(cfg) else None
    return {
        "k_pool": ParamSpec(
            (n_layers, batch, mb, BLOCK_SIZE, Hkv, D),
            ("layers", "batch", blocks_ax, None, "kv_heads", None),
            dtype=dtype, init="zeros"),
        "v_pool": ParamSpec(
            (n_layers, batch, mb, BLOCK_SIZE, Hkv, D),
            ("layers", "batch", blocks_ax, None, "kv_heads", None),
            dtype=dtype, init="zeros"),
    }


def _ssm_cache_specs(cfg, n_layers, batch):
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv_width
    DI = cfg.ssm_inner
    return {
        "state": ParamSpec((n_layers, batch, H, P, N),
                           ("layers", "batch", "ssm_heads", None, None),
                           dtype=jnp.float32, init="zeros"),
        "conv_x": ParamSpec((n_layers, batch, W - 1, DI),
                            ("layers", "batch", None, "ssm_inner"),
                            dtype=jnp.float32, init="zeros"),
        "conv_b": ParamSpec((n_layers, batch, W - 1, G * N),
                            ("layers", "batch", None, None),
                            dtype=jnp.float32, init="zeros"),
        "conv_c": ParamSpec((n_layers, batch, W - 1, G * N),
                            ("layers", "batch", None, None),
                            dtype=jnp.float32, init="zeros"),
    }


def cache_specs(
    cfg: ModelConfig, batch: int, max_seq: int, enc_len: int = 0,
    pool_slack: int = 0,
) -> Dict[str, Any]:
    """ParamSpec tree for the decode cache of this architecture.

    ``pool_slack`` adds spare pages per sequence beyond ceil(max_seq/block)
    (the serving engine's recycling headroom; the BlockPool hands out ids
    over the SAME range, asserted in the engine).
    """
    dtype = jnp.dtype(cfg.dtype)
    layout = cache_layout(cfg)
    if layout == "ssm":
        return {"layers": _ssm_cache_specs(cfg, cfg.num_layers, batch)}
    if layout == "hybrid":
        na = n_shared_attn(cfg)
        return {
            "layers": _ssm_cache_specs(cfg, cfg.num_layers, batch),
            "attn": _attn_cache_specs(cfg, na, batch, max_seq, "paged",
                                      dtype, pool_slack),
        }
    if cfg.is_encdec:
        Hkv = cfg.num_kv_heads or cfg.num_heads
        D = cfg.resolved_head_dim
        return {
            "self": _attn_cache_specs(cfg, cfg.num_layers, batch, max_seq,
                                      "paged", dtype, pool_slack),
            "cross_k": ParamSpec(
                (cfg.num_layers, batch, enc_len, Hkv, D),
                ("layers", "batch", "kv_seq", "kv_heads", None),
                dtype=dtype, init="zeros"),
            "cross_v": ParamSpec(
                (cfg.num_layers, batch, enc_len, Hkv, D),
                ("layers", "batch", "kv_seq", "kv_heads", None),
                dtype=dtype, init="zeros"),
            "enc_len": ParamSpec((batch,), ("batch",), dtype=jnp.int32,
                                 init="zeros"),
        }
    return {"layers": _attn_cache_specs(cfg, cfg.num_layers, batch, max_seq,
                                        layout, dtype, pool_slack)}


# ---------------------------------------------------------------------------
# Decoder-only stacks (dense / moe / vlm)
# ---------------------------------------------------------------------------
def _remat(body, policy: Optional[str]):
    if policy is None or policy == "none":
        return body
    if policy == "full":
        return jax.checkpoint(body)
    if policy == "dots":
        return jax.checkpoint(
            body,
            policy=jax.checkpoint_policies.checkpoint_dots_with_no_batch_dims,
        )
    raise ValueError(policy)


def run_decoder_stack(
    params, x, cfg: ModelConfig, *,
    constrain: Constrain = _id,
    remat: Optional[str] = None,
    emit_kv: bool = False,
    positions=None,
):
    """Full-sequence pass over stacked decoder layers via lax.scan."""

    def body(h, lp):
        h = constrain(h)
        a_in = L.apply_norm(lp["norm1"], h, cfg)
        a, kv = L.attention_full(
            lp["attn"], a_in, cfg, causal=True, positions=positions
        )
        h = h + a
        m_in = L.apply_norm(lp["norm2"], h, cfg)
        if cfg.family == "moe":
            m = L.apply_moe(lp["moe"], m_in, cfg)
        else:
            m = L.apply_mlp(lp["mlp"], m_in, cfg)
        h = h + m
        return h, (kv if emit_kv else None)

    x, kvs = jax.lax.scan(_remat(body, remat), x, params["layers"])
    return constrain(x), kvs


def run_ssm_stack(
    params, x, cfg: ModelConfig, *,
    constrain: Constrain = _id,
    remat: Optional[str] = None,
    emit_cache: bool = False,
):
    def body(h, lp):
        h = constrain(h)
        m_in = L.apply_norm(lp["norm"], h, cfg)
        m, cache = S.mamba_full(lp["mamba"], m_in, cfg)
        h = h + m
        return h, (cache if emit_cache else None)

    x, caches = jax.lax.scan(_remat(body, remat), x, params["layers"])
    return constrain(x), caches


def run_hybrid_stack(
    params, x, cfg: ModelConfig, *,
    constrain: Constrain = _id,
    remat: Optional[str] = None,
    emit_cache: bool = False,
    positions=None,
):
    """Zamba2-style: scan `attn_period`-sized groups of mamba layers, each
    followed by the *shared* attention block; trailing mamba layers after."""
    period = cfg.attn_period
    n_attn = n_shared_attn(cfg)
    n_grouped = n_attn * period
    shared = params["shared_attn"]

    def mamba_layer(h, lp):
        h = constrain(h)
        m_in = L.apply_norm(lp["norm"], h, cfg)
        m, cache = S.mamba_full(lp["mamba"], m_in, cfg)
        return h + m, (cache if emit_cache else None)

    def group_body(h, lp_group):
        h, caches = jax.lax.scan(mamba_layer, h, lp_group)
        a_in = L.apply_norm(shared["norm1"], h, cfg)
        a, kv = L.attention_full(shared["attn"], a_in, cfg, causal=True,
                                 positions=positions)
        h = h + a
        m_in = L.apply_norm(shared["norm2"], h, cfg)
        h = h + L.apply_mlp(shared["mlp"], m_in, cfg)
        return h, (caches, (kv if emit_cache else None))

    grouped = jax.tree.map(
        lambda a: a[:n_grouped].reshape((n_attn, period) + a.shape[1:]),
        params["layers"],
    )
    trailing = jax.tree.map(lambda a: a[n_grouped:], params["layers"])

    x, (gcaches, kvs) = jax.lax.scan(_remat(group_body, remat), x, grouped)
    n_trail = cfg.num_layers - n_grouped
    tcaches = None
    if n_trail:
        x, tcaches = jax.lax.scan(_remat(mamba_layer, remat), x, trailing)
    if not emit_cache:
        return constrain(x), None
    # flatten grouped caches (n_attn, period, B, ...) -> (L_grouped, B, ...)
    flat = jax.tree.map(
        lambda a: a.reshape((n_grouped,) + a.shape[2:]), gcaches
    )
    if n_trail:
        merged = jax.tree.map(
            lambda g, t: jnp.concatenate([g, t], 0), flat, tcaches
        )
    else:
        merged = flat
    return constrain(x), (merged, kvs)


def run_encoder_stack(params, x, cfg: ModelConfig, *,
                      constrain: Constrain = _id, remat=None):
    def body(h, lp):
        h = constrain(h)
        a_in = L.apply_norm(lp["norm1"], h, cfg)
        a, _ = L.attention_full(lp["attn"], a_in, cfg, causal=False)
        h = h + a
        m_in = L.apply_norm(lp["norm2"], h, cfg)
        h = h + L.apply_mlp(lp["mlp"], m_in, cfg)
        return h, None

    x, _ = jax.lax.scan(_remat(body, remat), x, params["enc_layers"])
    return constrain(x)


def run_decoder_xattn_stack(params, x, enc_out, cfg: ModelConfig, *,
                            constrain: Constrain = _id, remat=None,
                            emit_kv: bool = False):
    def body(h, lp):
        h = constrain(h)
        a_in = L.apply_norm(lp["norm1"], h, cfg)
        a, self_kv = L.attention_full(lp["self_attn"], a_in, cfg,
                                      causal=True)
        h = h + a
        x_in = L.apply_norm(lp["norm_x"], h, cfg)
        xa, cross_kv = L.attention_full(lp["cross_attn"], x_in, cfg,
                                        causal=False, kv_x=enc_out)
        h = h + xa
        m_in = L.apply_norm(lp["norm2"], h, cfg)
        h = h + L.apply_mlp(lp["mlp"], m_in, cfg)
        return h, ((self_kv, cross_kv) if emit_kv else None)

    x, kvs = jax.lax.scan(_remat(body, remat), x, params["dec_layers"])
    return constrain(x), kvs


# ---------------------------------------------------------------------------
# Forward passes
# ---------------------------------------------------------------------------
def _embed_inputs(params, batch, cfg: ModelConfig):
    """Token (+ frontend stub) embedding -> (B, S, M) residual stream."""
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
    if cfg.family == "vlm" and "frontend_embeds" in batch:
        fe = batch["frontend_embeds"].astype(x.dtype)
        x = jnp.concatenate([fe, x], axis=1)
    return x


def forward_train(params, batch, cfg: ModelConfig, *,
                  constrain: Constrain = _id,
                  remat: Optional[str] = "full"):
    """Next-token LM loss (enc-dec: seq2seq loss on the decoder)."""
    if cfg.is_encdec:
        enc = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
        enc_out = run_encoder_stack(params, enc, cfg, constrain=constrain,
                                    remat=remat)
        enc_out = L.apply_norm(params["enc_norm"], enc_out, cfg)
        x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
        x, _ = run_decoder_xattn_stack(params, x, enc_out, cfg,
                                       constrain=constrain, remat=remat)
    elif cfg.family == "ssm":
        x = _embed_inputs(params, batch, cfg)
        x, _ = run_ssm_stack(params, x, cfg, constrain=constrain,
                             remat=remat)
    elif cfg.family == "hybrid":
        x = _embed_inputs(params, batch, cfg)
        x, _ = run_hybrid_stack(params, x, cfg, constrain=constrain,
                                remat=remat)
    else:
        x = _embed_inputs(params, batch, cfg)
        x, _ = run_decoder_stack(params, x, cfg, constrain=constrain,
                                 remat=remat)
    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x, cfg)

    labels = batch["labels"]
    if cfg.family == "vlm" and "frontend_embeds" in batch:
        # loss only on text positions (labels already text-aligned)
        n_front = batch["frontend_embeds"].shape[1]
        logits = logits[:, n_front:]
    lf = logits.astype(jnp.float32)
    lse = jax.nn.logsumexp(lf, axis=-1)
    gold = jnp.take_along_axis(lf, labels[..., None], axis=-1)[..., 0]
    nll = lse - gold
    mask = batch.get("loss_mask")
    if mask is None:
        mask = jnp.ones_like(nll)
    loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    return loss, {"loss": loss, "ntokens": mask.sum()}


def forward_prefill(params, batch, cfg: ModelConfig, *,
                    constrain: Constrain = _id):
    """Prefill: full-sequence pass emitting last-position logits + the KV /
    state caches (contiguous; the engine pages them into the BlockPool).

    ``batch["last_index"]`` (B,) optionally selects the per-sequence logit
    position (padded prompts in the serving engine); default: position -1.
    """
    if cfg.is_encdec:
        enc = batch["enc_embeds"].astype(jnp.dtype(cfg.dtype))
        enc_out = run_encoder_stack(params, enc, cfg, constrain=constrain)
        enc_out = L.apply_norm(params["enc_norm"], enc_out, cfg)
        x = L.embed_tokens(params["embed"], batch["tokens"], cfg)
        x, kvs = run_decoder_xattn_stack(params, x, enc_out, cfg,
                                         constrain=constrain, emit_kv=True)
        cache = {
            "self_k": kvs[0][0], "self_v": kvs[0][1],
            "cross_k": kvs[1][0], "cross_v": kvs[1][1],
        }
    elif cfg.family == "ssm":
        x = _embed_inputs(params, batch, cfg)
        x, caches = run_ssm_stack(params, x, cfg, constrain=constrain,
                                  emit_cache=True)
        cache = caches
    elif cfg.family == "hybrid":
        x = _embed_inputs(params, batch, cfg)
        x, (mcache, kvs) = run_hybrid_stack(params, x, cfg,
                                            constrain=constrain,
                                            emit_cache=True)
        cache = {"mamba": mcache, "attn_k": kvs[0], "attn_v": kvs[1]}
    else:
        x = _embed_inputs(params, batch, cfg)
        x, kvs = run_decoder_stack(params, x, cfg, constrain=constrain,
                                   emit_kv=True)
        cache = {"k": kvs[0], "v": kvs[1]}
    x = L.apply_norm(params["final_norm"], x, cfg)
    last_index = batch.get("last_index")
    if last_index is None:
        x_last = x[:, -1]
    else:
        x_last = jnp.take_along_axis(
            x, last_index[:, None, None].astype(jnp.int32), axis=1
        )[:, 0]
    logits_last = L.unembed(params["embed"], x_last, cfg)
    return logits_last, cache


def prefill_chunk(params, cache, batch, cfg: ModelConfig, *,
                  n_kv: Optional[int] = None,
                  global_pages: bool = False):
    """One chunk of an incremental (chunked) prefill for paged layouts.

    Processes ``C = tokens.shape[1]`` prompt positions starting at absolute
    position ``start`` for one slot: every layer scatters the chunk's K/V
    into the slot's pool pages (``pages``, chunk-offset blocks; spare
    entries point at the scratch page 0) and attends the chunk's queries
    causally over the slot's paged prior KV + the chunk itself
    (:func:`repro.models.layers.attention_chunk`).  Numerics are
    bit-identical to a whole-prompt :func:`forward_prefill` of the same
    tokens at every valid position — chunking changes the schedule, never
    the math.

    ``batch``: {"tokens": (1, C) int32, "start": scalar int32,
                "slot": scalar int32, "row": (mb,) int32 block-table row,
                "pages": (C // BLOCK_SIZE,) int32,
                "last_index": scalar int32 — position of the final prompt
                token WITHIN the chunk (only read on the last chunk)}
    ``n_kv`` (static) bounds the prior-KV page sweep, exactly as in
    :func:`decode_step`.  Returns (logits (1, V) at ``last_index``,
    new_cache).
    """
    assert cache_layout(cfg) == "paged", "chunked prefill is paged-only"
    tokens = batch["tokens"]
    C = tokens.shape[1]
    slot, row, pages = batch["slot"], batch["row"], batch["pages"]
    if n_kv is None:
        n_kv = row.shape[0]
    positions = batch["start"] + jnp.arange(C, dtype=jnp.int32)
    x = L.embed_tokens(params["embed"], tokens, cfg)

    def body(h, xs):
        lp, cl = xs
        a_in = L.apply_norm(lp["norm1"], h, cfg)
        a, new_c = L.attention_chunk(
            lp["attn"], a_in, cfg, cl, slot=slot, row=row, pages=pages,
            positions=positions, n_kv=n_kv, global_pages=global_pages)
        h = h + a
        m_in = L.apply_norm(lp["norm2"], h, cfg)
        if cfg.family == "moe":
            m = L.apply_moe(lp["moe"], m_in, cfg)
        else:
            m = L.apply_mlp(lp["mlp"], m_in, cfg)
        return h + m, new_c

    x, new_layers = jax.lax.scan(body, x,
                                 (params["layers"], cache["layers"]))
    x = L.apply_norm(params["final_norm"], x, cfg)
    last = jnp.reshape(batch["last_index"], (1, 1, 1)).astype(jnp.int32)
    x_last = jnp.take_along_axis(x, last, axis=1)[:, 0]
    logits = L.unembed(params["embed"], x_last, cfg)
    return logits, dict(cache, layers=new_layers)


# ---------------------------------------------------------------------------
# Decode steps
# ---------------------------------------------------------------------------
def decode_step(params, cache, batch, cfg: ModelConfig, *,
                n_kv: Optional[int] = None,
                global_pages: bool = False):
    """One token for every sequence in the batch against the cache.

    ``batch``: {"tokens": (B,1) int32, "lengths": (B,) int32,
                "block_table": (B, MB) int32 (paged layouts only)}
    ``n_kv`` (static) bounds the paged KV sweep (see kernels/ops.py).
    ``global_pages``: block-table entries are slot-flattened global page
    ids (copy-on-write forks; see layers.attention_decode).
    Returns (logits (B, V), new_cache).
    """
    lengths = batch["lengths"]
    block_table = batch.get("block_table")
    x = L.embed_tokens(params["embed"], batch["tokens"], cfg)

    layout = cache_layout(cfg)
    if cfg.is_encdec:
        def body(h, xs):
            lp, cl = xs
            a_in = L.apply_norm(lp["norm1"], h, cfg)
            a, new_self = L.attention_decode(
                lp["self_attn"], a_in, cfg,
                {"k_pool": cl["sk"], "v_pool": cl["sv"]}, lengths,
                block_table=block_table, n_kv=n_kv,
                global_pages=global_pages)
            h = h + a
            x_in = L.apply_norm(lp["norm_x"], h, cfg)
            xa, _ = L.attention_decode(
                lp["cross_attn"], x_in, cfg,
                {"k": cl["ck"], "v": cl["cv"], "len": cache["enc_len"]},
                lengths, cross=True)
            h = h + xa
            m_in = L.apply_norm(lp["norm2"], h, cfg)
            h = h + L.apply_mlp(lp["mlp"], m_in, cfg)
            return h, {"sk": new_self["k_pool"], "sv": new_self["v_pool"]}

        xs = (params["dec_layers"], {
            "sk": cache["self"]["k_pool"], "sv": cache["self"]["v_pool"],
            "ck": cache["cross_k"], "cv": cache["cross_v"]})
        x, new = jax.lax.scan(body, x, xs)
        new_cache = dict(cache)
        new_cache["self"] = {"k_pool": new["sk"], "v_pool": new["sv"]}
    elif layout == "ssm":
        def body(h, xs):
            lp, cl = xs
            m_in = L.apply_norm(lp["norm"], h, cfg)
            m, new_c = S.mamba_decode(lp["mamba"], m_in, cfg, cl)
            return h + m, new_c

        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_layers)
    elif layout == "hybrid":
        period = cfg.attn_period
        n_attn = n_shared_attn(cfg)
        n_grouped = n_attn * period
        shared = params["shared_attn"]

        def mamba_body(h, xs):
            lp, cl = xs
            m_in = L.apply_norm(lp["norm"], h, cfg)
            m, new_c = S.mamba_decode(lp["mamba"], m_in, cfg, cl)
            return h + m, new_c

        def group_body(h, xs):
            lp_group, cl_group, acl = xs
            h, new_mc = jax.lax.scan(mamba_body, h, (lp_group, cl_group))
            a_in = L.apply_norm(shared["norm1"], h, cfg)
            a, new_ac = L.attention_decode(
                shared["attn"], a_in, cfg, acl, lengths,
                block_table=block_table, n_kv=n_kv,
                global_pages=global_pages)
            h = h + a
            m_in = L.apply_norm(shared["norm2"], h, cfg)
            h = h + L.apply_mlp(shared["mlp"], m_in, cfg)
            return h, (new_mc, new_ac)

        lp_g = jax.tree.map(
            lambda a: a[:n_grouped].reshape((n_attn, period) + a.shape[1:]),
            params["layers"])
        cl_g = jax.tree.map(
            lambda a: a[:n_grouped].reshape((n_attn, period) + a.shape[1:]),
            cache["layers"])
        x, (new_mc_g, new_ac) = jax.lax.scan(
            group_body, x, (lp_g, cl_g, cache["attn"]))
        n_trail = cfg.num_layers - n_grouped
        new_mc_g = jax.tree.map(
            lambda a: a.reshape((n_grouped,) + a.shape[2:]), new_mc_g)
        if n_trail:
            lp_t = jax.tree.map(lambda a: a[n_grouped:], params["layers"])
            cl_t = jax.tree.map(lambda a: a[n_grouped:], cache["layers"])
            x, new_mc_t = jax.lax.scan(mamba_body, x, (lp_t, cl_t))
            new_mc = jax.tree.map(
                lambda g, t: jnp.concatenate([g, t], 0), new_mc_g, new_mc_t)
        else:
            new_mc = new_mc_g
        new_cache = dict(cache, layers=new_mc, attn=new_ac)
    else:
        def body(h, xs):
            lp, cl = xs
            a_in = L.apply_norm(lp["norm1"], h, cfg)
            a, new_c = L.attention_decode(lp["attn"], a_in, cfg, cl,
                                          lengths, block_table=block_table,
                                          n_kv=n_kv,
                                          global_pages=global_pages)
            h = h + a
            m_in = L.apply_norm(lp["norm2"], h, cfg)
            if cfg.family == "moe":
                m = L.apply_moe(lp["moe"], m_in, cfg)
            else:
                m = L.apply_mlp(lp["mlp"], m_in, cfg)
            return h + m, new_c

        x, new_layers = jax.lax.scan(body, x,
                                     (params["layers"], cache["layers"]))
        new_cache = dict(cache, layers=new_layers)

    x = L.apply_norm(params["final_norm"], x, cfg)
    logits = L.unembed(params["embed"], x[:, 0], cfg)
    return logits, new_cache
