"""Mamba2 (state-space duality) blocks — used by mamba2-2.7b and the
zamba2-7b hybrid.

Projections are kept per-component (z / x / B / C / dt) instead of one fused
in_proj so the tensor-parallel dim (``ssm_inner``) shards cleanly without
slicing a sharded dimension at non-boundary offsets.
"""

from __future__ import annotations

from typing import Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig
from ..kernels import ops
from .param import ParamSpec


def mamba_specs(cfg: ModelConfig, layers: int) -> Dict[str, ParamSpec]:
    M = cfg.d_model
    DI = cfg.ssm_inner
    H = cfg.ssm_heads
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv_width
    lead = (layers,) if layers else ()
    la = ("layers",) if layers else ()
    return {
        "wz": ParamSpec(lead + (M, DI), la + ("embed", "ssm_inner"),
                        init="scaled"),
        "wx": ParamSpec(lead + (M, DI), la + ("embed", "ssm_inner"),
                        init="scaled"),
        "wb": ParamSpec(lead + (M, G * N), la + ("embed", None),
                        init="scaled"),
        "wc": ParamSpec(lead + (M, G * N), la + ("embed", None),
                        init="scaled"),
        "wdt": ParamSpec(lead + (M, H), la + ("embed", None), init="scaled"),
        "dt_bias": ParamSpec(lead + (H,), la + (None,), init="zeros"),
        "a_log": ParamSpec(lead + (H,), la + (None,), init="zeros"),
        "d_skip": ParamSpec(lead + (H,), la + (None,), init="ones"),
        "conv_x": ParamSpec(lead + (W, DI), la + ("conv", "ssm_inner"),
                            init="scaled"),
        "conv_b": ParamSpec(lead + (W, G * N), la + ("conv", None),
                            init="scaled"),
        "conv_c": ParamSpec(lead + (W, G * N), la + ("conv", None),
                            init="scaled"),
        "norm": ParamSpec(lead + (DI,), la + ("ssm_inner",), init="ones"),
        "wo": ParamSpec(lead + (DI, M), la + ("ssm_inner", "embed"),
                        init="scaled"),
    }


def _causal_conv(x: jax.Array, w: jax.Array) -> jax.Array:
    """Depthwise causal conv: x (B,S,C), w (W,C) -> (B,S,C)."""
    W = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (W - 1, 0), (0, 0)))
    out = jnp.zeros_like(x)
    S = x.shape[1]
    for i in range(W):  # W is tiny (4); unrolled shifts, no conv primitive
        out = out + xp[:, i : i + S, :] * w[i][None, None, :]
    return out


def _conv_step(
    state: jax.Array, x_new: jax.Array, w: jax.Array
) -> Tuple[jax.Array, jax.Array]:
    """Single-token conv: state (B,W-1,C), x_new (B,C) -> (out, new_state)."""
    W = w.shape[0]
    window = jnp.concatenate([state, x_new[:, None, :]], axis=1)  # (B,W,C)
    out = jnp.einsum("bwc,wc->bc", window, w)
    return out, window[:, 1:, :]


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float) -> jax.Array:
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(yf), -1, keepdims=True)
    return (yf * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32)).astype(
        y.dtype
    )


def mamba_full(
    p,
    xin: jax.Array,  # (B, S, M)
    cfg: ModelConfig,
    *,
    init_state: Optional[jax.Array] = None,
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    """Full-sequence Mamba2 block.  Returns (out, cache_slice) where the
    cache slice carries the final SSM state + conv tails for decode."""
    B, S, M = xin.shape
    dt_ = xin.dtype
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    W = cfg.ssm_conv_width

    z = jnp.einsum("bsm,md->bsd", xin, p["wz"].astype(dt_))
    x = jnp.einsum("bsm,md->bsd", xin, p["wx"].astype(dt_))
    b = jnp.einsum("bsm,mn->bsn", xin, p["wb"].astype(dt_))
    c = jnp.einsum("bsm,mn->bsn", xin, p["wc"].astype(dt_))
    dt = jnp.einsum("bsm,mh->bsh", xin, p["wdt"].astype(dt_))

    x_tail = x[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        x, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    b_tail = b[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        b, ((0, 0), (W - 1 - S, 0), (0, 0))
    )
    c_tail = c[:, -(W - 1):, :] if S >= W - 1 else jnp.pad(
        c, ((0, 0), (W - 1 - S, 0), (0, 0))
    )

    x = jax.nn.silu(_causal_conv(x, p["conv_x"].astype(dt_)))
    b = jax.nn.silu(_causal_conv(b, p["conv_b"].astype(dt_)))
    c = jax.nn.silu(_causal_conv(c, p["conv_c"].astype(dt_)))

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))  # (H,) negative

    y, final_state = ops.ssd_chunk_scan(
        x.reshape(B, S, H, P),
        dt,
        a,
        b.reshape(B, S, G, N),
        c.reshape(B, S, G, N),
        chunk=min(cfg.ssm_chunk, S),
        d_skip=p["d_skip"],
        init_state=init_state,
    )
    y = y.reshape(B, S, H * P)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bsd,dm->bsm", y, p["wo"].astype(dt_))
    cache = {
        "state": final_state,  # (B, H, P, N) f32
        "conv_x": x_tail,
        "conv_b": b_tail,
        "conv_c": c_tail,
    }
    return out, cache


def mamba_decode(
    p,
    xin: jax.Array,  # (B, 1, M)
    cfg: ModelConfig,
    cache: Dict[str, jax.Array],
) -> Tuple[jax.Array, Dict[str, jax.Array]]:
    B = xin.shape[0]
    dt_ = xin.dtype
    H, P = cfg.ssm_heads, cfg.ssm_head_dim
    G, N = cfg.ssm_groups, cfg.ssm_state
    x1 = xin[:, 0]

    z = jnp.einsum("bm,md->bd", x1, p["wz"].astype(dt_))
    x = jnp.einsum("bm,md->bd", x1, p["wx"].astype(dt_))
    b = jnp.einsum("bm,mn->bn", x1, p["wb"].astype(dt_))
    c = jnp.einsum("bm,mn->bn", x1, p["wc"].astype(dt_))
    dt = jnp.einsum("bm,mh->bh", x1, p["wdt"].astype(dt_))

    x_conv, conv_x = _conv_step(cache["conv_x"], x, p["conv_x"].astype(dt_))
    b_conv, conv_b = _conv_step(cache["conv_b"], b, p["conv_b"].astype(dt_))
    c_conv, conv_c = _conv_step(cache["conv_c"], c, p["conv_c"].astype(dt_))
    x_conv = jax.nn.silu(x_conv)
    b_conv = jax.nn.silu(b_conv)
    c_conv = jax.nn.silu(c_conv)

    dt = jax.nn.softplus(
        dt.astype(jnp.float32) + p["dt_bias"].astype(jnp.float32)
    )
    a = -jnp.exp(p["a_log"].astype(jnp.float32))

    y, state = ops.ssd_decode_step(
        x_conv.reshape(B, H, P),
        dt,
        a,
        b_conv.reshape(B, G, N),
        c_conv.reshape(B, G, N),
        cache["state"],
        d_skip=p["d_skip"],
    )
    y = y.reshape(B, H * P)
    y = _gated_norm(y, z, p["norm"], cfg.norm_eps)
    out = jnp.einsum("bd,dm->bm", y, p["wo"].astype(dt_))
    new_cache = {
        "state": state,
        "conv_x": conv_x.astype(jnp.float32),
        "conv_b": conv_b.astype(jnp.float32),
        "conv_c": conv_c.astype(jnp.float32),
    }
    # cast back: the f32 conv-state path must not promote the residual
    return out[:, None].astype(dt_), new_cache
