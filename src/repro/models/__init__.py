from .model import Model
from .param import (
    ParamSpec,
    abstract_params,
    count_params,
    init_params,
    tree_map_specs,
)

__all__ = [
    "Model", "ParamSpec", "abstract_params", "count_params", "init_params",
    "tree_map_specs",
]
