"""Model facade: one object per architecture config exposing everything the
launcher, trainer, server, dry-run and tests need.

The dry-run never materializes arrays: ``abstract_params`` /
``abstract_inputs`` / ``abstract_cache`` return ShapeDtypeStruct trees, and
the parallel ``*_axes`` trees give logical axes for the sharding rules.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from ..configs.base import ModelConfig, ShapeConfig
from . import transformer as T
from .param import (
    ParamSpec,
    abstract_params,
    count_params,
    init_params,
    tree_map_specs,
)

#: fixed encoder length for enc-dec *decode* shapes (audio frames; doc'd in
#: DESIGN.md — the decoder cache, not the encoder, is the scaling axis)
ENCDEC_DECODE_ENC_LEN = 4096


class Model:
    def __init__(self, cfg: ModelConfig):
        self.cfg = cfg
        self.param_specs = T.build_specs(cfg)

    # ------------------------------------------------------------------
    # Params
    # ------------------------------------------------------------------
    def abstract_params(self):
        return abstract_params(self.param_specs)

    def init_params(self, seed: int = 0):
        return init_params(self.param_specs, seed)

    def n_params(self) -> int:
        return count_params(self.param_specs)

    # ------------------------------------------------------------------
    # Inputs
    # ------------------------------------------------------------------
    def input_specs(self, shape: ShapeConfig) -> Dict[str, ParamSpec]:
        """ParamSpec tree for the step inputs of this (arch, shape) cell."""
        cfg = self.cfg
        B, S = shape.global_batch, shape.seq_len
        ii = jnp.int32

        if shape.kind == "train":
            if cfg.is_encdec:
                return {
                    "enc_embeds": ParamSpec((B, S, cfg.d_model),
                                            ("batch", None, None),
                                            dtype=jnp.dtype(cfg.dtype)),
                    "tokens": ParamSpec((B, S), ("batch", None), dtype=ii,
                                        init="zeros"),
                    "labels": ParamSpec((B, S), ("batch", None), dtype=ii,
                                        init="zeros"),
                }
            if cfg.family == "vlm":
                P = cfg.frontend_positions
                return {
                    "frontend_embeds": ParamSpec((B, P, cfg.d_model),
                                                 ("batch", None, None),
                                                 dtype=jnp.dtype(cfg.dtype)),
                    "tokens": ParamSpec((B, S - P), ("batch", None),
                                        dtype=ii, init="zeros"),
                    "labels": ParamSpec((B, S - P), ("batch", None),
                                        dtype=ii, init="zeros"),
                }
            return {
                "tokens": ParamSpec((B, S), ("batch", None), dtype=ii,
                                    init="zeros"),
                "labels": ParamSpec((B, S), ("batch", None), dtype=ii,
                                    init="zeros"),
            }

        if shape.kind == "prefill":
            specs = {
                "tokens": ParamSpec((B, S), ("batch", None), dtype=ii,
                                    init="zeros")
            }
            if cfg.is_encdec:
                specs["enc_embeds"] = ParamSpec(
                    (B, S, cfg.d_model), ("batch", None, None),
                    dtype=jnp.dtype(cfg.dtype))
            elif cfg.family == "vlm":
                P = cfg.frontend_positions
                specs["tokens"] = ParamSpec((B, S - P), ("batch", None),
                                            dtype=ii, init="zeros")
                specs["frontend_embeds"] = ParamSpec(
                    (B, P, cfg.d_model), ("batch", None, None),
                    dtype=jnp.dtype(cfg.dtype))
            return specs

        # decode
        specs = {
            "tokens": ParamSpec((B, 1), ("batch", None), dtype=ii,
                                init="zeros"),
            "lengths": ParamSpec((B,), ("batch",), dtype=ii, init="zeros"),
        }
        if self.uses_block_table():
            mb = -(-S // T.BLOCK_SIZE) + 1
            specs["block_table"] = ParamSpec((B, mb), ("batch", None),
                                             dtype=ii, init="zeros")
        return specs

    def uses_block_table(self) -> bool:
        layout = T.cache_layout(self.cfg)
        return layout in ("paged", "hybrid") or self.cfg.is_encdec

    def cache_specs(self, shape: ShapeConfig, pool_slack: int = 0):
        enc_len = ENCDEC_DECODE_ENC_LEN if self.cfg.is_encdec else 0
        return T.cache_specs(self.cfg, shape.global_batch, shape.seq_len,
                             enc_len=enc_len, pool_slack=pool_slack)

    # ------------------------------------------------------------------
    # Forward entry points
    # ------------------------------------------------------------------
    def loss_fn(self, params, batch, *, constrain=T._id, remat="full"):
        return T.forward_train(params, batch, self.cfg,
                               constrain=constrain, remat=remat)

    def prefill(self, params, batch, *, constrain=T._id):
        return T.forward_prefill(params, batch, self.cfg,
                                 constrain=constrain)

    def prefill_chunk(self, params, cache, batch, *, n_kv=None,
                      global_pages=False):
        """One chunk of an incremental prefill against the paged decode
        cache (serving hot path; see :func:`repro.models.transformer.
        prefill_chunk`).  ``n_kv`` (static int) bounds the prior-KV page
        sweep like :meth:`decode_step`."""
        return T.prefill_chunk(params, cache, batch, self.cfg, n_kv=n_kv,
                               global_pages=global_pages)

    def decode_step(self, params, cache, batch, *, n_kv=None,
                    global_pages=False):
        """``n_kv`` (static int) bounds the paged-attention KV sweep to the
        first ``n_kv`` block-table columns (serving hot path).
        ``global_pages`` (static bool) switches block-table entries to
        slot-flattened global page ids (copy-on-write forks)."""
        return T.decode_step(params, cache, batch, self.cfg, n_kv=n_kv,
                             global_pages=global_pages)

    # ------------------------------------------------------------------
    # Synthetic batches (smoke tests / examples / data pipeline)
    # ------------------------------------------------------------------
    def synthetic_batch(self, shape: ShapeConfig, seed: int = 0):
        cfg = self.cfg
        specs = self.input_specs(shape)

        def make(path, s: ParamSpec):
            key = jax.random.PRNGKey(
                (seed * 9973 + hash(path)) % (2**31)
            )
            if s.dtype == jnp.int32:
                if path == "lengths":
                    # mid-cache decode position
                    return jnp.full(s.shape, shape.seq_len // 2, jnp.int32)
                if path == "block_table":
                    B, mb = s.shape
                    return jnp.tile(jnp.arange(mb, dtype=jnp.int32), (B, 1))
                return jax.random.randint(key, s.shape, 0, cfg.vocab_size,
                                          jnp.int32)
            return jax.random.normal(key, s.shape, jnp.float32).astype(
                s.dtype) * 0.02

        return tree_map_specs(make, specs)

    def init_cache(self, shape: ShapeConfig, seed: int = 0,
                   pool_slack: int = 0):
        """Materialized zero cache (smoke tests / serving engine)."""
        return init_params(self.cache_specs(shape, pool_slack), seed)
